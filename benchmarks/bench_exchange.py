"""Collective-traffic suite — rolled vs ppermute exchange backends (suite X).

Compiles one CHOCO gossip round (and one full AD-GDA train step) per
{topology x compressor x backend} on an 8-device node-sharded CPU mesh and
reads the *optimized per-partition HLO* with ``launch/hlo_cost.py``:

* the ``ppermute`` backend must move collective-permute bytes ≈ **degree x
  compressed payload** per device — the wire model the paper's
  communication-efficiency claims assume (per-link, per-round accounting a
  la DRFA/DR-DSGD), with zero all-gather traffic;
* the ``rolled`` backend simulates the network on the stacked array, and at
  m >= 8 GSPMD turns parts of it into all-gathers of the whole stacked
  payload — its estimated transmitted bytes (``Cost.wire_bytes``) must be
  *strictly above* the ppermute backend's for every scenario.

Both assertions run inside the suite (a regression fails the benchmark, and
CI runs it on the quick tier).  Device count must be fixed before jax
initializes, so ``run()`` re-executes this module as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; rows are persisted
to BENCH_X.json by ``benchmarks.run`` like every suite.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

M = 8  # nodes == devices: every topology family is exercisable (block = 1)
_MARK = "BENCH_X_JSON:"


def run(quick: bool = True) -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + env.get("XLA_FLAGS", "")
    ).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [env.get("PYTHONPATH"), _repo_src(), _repo_root()] if p
    )
    cmd = [sys.executable, "-m", "benchmarks.bench_exchange", "--child"]
    if not quick:
        cmd.append("--full")
    proc = subprocess.run(
        cmd, env=env, cwd=_repo_root(), capture_output=True, text=True
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_exchange child failed (rc={proc.returncode}):\n"
            f"{proc.stdout[-4000:]}\n{proc.stderr[-4000:]}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(f"bench_exchange child printed no rows:\n{proc.stdout[-2000:]}")


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _repo_src() -> str:
    return os.path.join(_repo_root(), "src")


# ---------------------------------------------------------------- child side
def _payload_bytes(spec: str, d: int) -> float:
    """Per-neighbor wire bytes of one compressed leaf payload.

    kq*b: bit-packed levels (bits/8 B/elem) + sign bitmask (1/8 B/elem) +
    one f32 norm; q*b: the unpacked reference wire format (uint8 level +
    bool sign per element + one f32 norm).
    """
    if spec.startswith("kq"):
        bits = int(spec[2:-1])
        return d * bits / 8.0 + d / 8.0 + 4.0
    if spec.startswith("q"):
        return 2.0 * d + 4.0
    raise ValueError(spec)


def _child(quick: bool) -> list[dict]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import gossip
    from repro.core.compression import make_compressor
    from repro.core.topology import make_topology
    from repro.launch.hlo_cost import analyze_compiled
    from repro.launch.mesh import make_cpu_mesh
    from repro.launch.sharding import node_shardings

    assert len(jax.devices()) >= M, "child must run with 8 forced host devices"
    mesh = make_cpu_mesh(data=M)
    d = 1 << 14 if quick else 1 << 16
    theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (M, d))}
    state = gossip.choco_init(theta)
    key = jax.random.PRNGKey(1)
    repl = NamedSharding(mesh, P())
    stree = lambda t: node_shardings(t, mesh, M)

    rows: list[dict] = []
    scenarios = [("ring", "kq4b"), ("torus", "kq4b"), ("erdos_renyi", "kq4b"),
                 ("ring", "q4b"), ("erdos_renyi", "q4b")]
    if not quick:
        scenarios += [("torus", "q4b"), ("ring", "kq8b")]
    for topo_name, spec in scenarios:
        topo = make_topology(topo_name, M)
        comp = make_compressor(spec)
        per_backend = {}
        for backend in ("rolled", "ppermute"):
            kw = dict(packed=True)
            if backend == "ppermute":
                kw.update(backend="ppermute", mesh=mesh)
            fn = lambda t, s, k: gossip.choco_round(t, s, topo, 0.2, comp, k, **kw)
            compiled = (
                jax.jit(fn, in_shardings=(stree(theta), stree(state), repl))
                .lower(theta, state, key)
                .compile()
            )
            cost = analyze_compiled(compiled)
            per_backend[backend] = cost
            rows.append({
                "table": "X",
                "scenario": "choco_round",
                "topology": topo_name,
                "compressor": spec,
                "backend": backend,
                "d": d,
                "coll_permute_bytes": cost.coll["collective-permute"],
                "all_gather_bytes": cost.coll["all-gather"],
                "coll_operand_bytes": cost.coll_bytes,
                "wire_bytes": cost.wire_bytes(M),
                "expected_wire_bytes": topo.max_degree * _payload_bytes(spec, d),
            })
        # --- the wire-model assertions (the point of this suite) ----------
        pp, ro = per_backend["ppermute"], per_backend["rolled"]
        expect = topo.max_degree * _payload_bytes(spec, d)
        cp = pp.coll["collective-permute"]
        assert pp.coll["all-gather"] == 0.0, (
            f"{topo_name}/{spec}: ppermute backend emitted all-gather bytes "
            f"({pp.coll['all-gather']:.0f}) — the wire model leaked"
        )
        assert 0.9 * expect <= cp <= 1.6 * expect, (
            f"{topo_name}/{spec}: ppermute collective-permute bytes {cp:.0f} "
            f"not ~ degree x payload ({expect:.0f})"
        )
        assert pp.wire_bytes(M) < ro.wire_bytes(M), (
            f"{topo_name}/{spec}: ppermute wire bytes {pp.wire_bytes(M):.0f} "
            f"not strictly below rolled {ro.wire_bytes(M):.0f} at m={M}"
        )

    rows += _masked_round_rows(mesh, d, quick)
    rows += _gt_round_rows(mesh, d, quick)
    rows += _exact_sched_rows(mesh, d if quick else 1 << 14)
    rows += _baseline_rows(mesh, d if quick else 1 << 14)
    rows += _train_step_rows(mesh, d if quick else 1 << 14)
    return rows


def _gt_round_rows(mesh, d: int, quick: bool) -> list[dict]:
    """Multi-lane wire cost: one gradient-tracking round ships the model
    hat-delta AND the tracker hat-delta as a two-lane message over the same
    neighbor permutes.  Per edge that must cost <= 2.1x the single-lane
    compressed payload (two lanes at ~1x each plus the scheduled wire's
    float overhead) with zero all-gather — the ISSUE-8 acceptance bar."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.compression import make_compressor
    from repro.core.topology import make_topology, make_topology_schedule
    from repro.core.trainer import GradientTrackingConsensus
    from repro.launch.hlo_cost import analyze_compiled
    from repro.launch.sharding import node_shardings

    repl = NamedSharding(mesh, P())
    scenarios = [("gt_round_static", "ring", "kq4b")]
    if not quick:
        scenarios += [("gt_round_static", "ring", "q4b")]
    scenarios += [("gt_round_sched", "roundrobin:ring,torus", "kq4b")]

    rows = []
    for sname, spec, cspec in scenarios:
        comp = make_compressor(cspec)
        scheduled = sname.endswith("sched")
        if scheduled:
            topo = make_topology_schedule(spec, M)
        else:
            topo = make_topology(spec, M)
        gc = GradientTrackingConsensus(topo, comp, 0.2, backend="ppermute",
                                       mesh=mesh)
        theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (M, d))}
        theta_prev = {"w": jnp.zeros((M, d))}
        state = gc.init(theta)
        key = jax.random.PRNGKey(1)
        stree = lambda t: node_shardings(t, mesh, M)

        def fn(t, tp, s, k, step=None):
            return gc.mix(t, s, k, None, step=step, theta_prev=tp)

        args = [theta, theta_prev, state, key]
        shards = [stree(theta), stree(theta_prev), stree(state), repl]
        if scheduled:
            args.append(jnp.int32(1))
            shards.append(repl)
        compiled = (
            jax.jit(fn, in_shardings=tuple(shards)).lower(*args).compile()
        )
        cost = analyze_compiled(compiled)
        cp = cost.coll["collective-permute"]
        edges = gc.union.max_out_degree if scheduled else topo.max_degree
        payload = _payload_bytes(cspec, d)
        rows.append({
            "table": "X",
            "scenario": sname,
            "topology": spec,
            "compressor": cspec,
            "backend": "ppermute",
            "d": d,
            "coll_permute_bytes": cp,
            "all_gather_bytes": cost.coll["all-gather"],
            "coll_operand_bytes": cost.coll_bytes,
            "wire_bytes": cost.wire_bytes(M),
            "expected_wire_bytes": 2.0 * edges * payload,
            "per_edge_bytes": cp / edges,
            "per_edge_payload_bytes": payload,
        })
        assert cost.coll["all-gather"] == 0.0, (
            f"{sname}/{cspec}: two-lane gt round emitted all-gather bytes "
            f"({cost.coll['all-gather']:.0f}) — the multi-lane wire leaked"
        )
        assert cp / edges <= 2.1 * payload, (
            f"{sname}/{cspec}: two-lane per-edge bytes {cp / edges:.0f} "
            f"exceed 2.1x the single-lane compressed payload ({payload:.0f})"
        )
    return rows


def _exact_sched_rows(mesh, d: int) -> list[dict]:
    """Per-phase wire program for scheduled ExactConsensus: the dense mix
    under ``lax.switch`` must bill only the busiest *phase's* edges (HLO
    conditionals cost their most expensive branch), not the whole union —
    on a 4-phase one-peer matching schedule that is a ~P x traffic cut vs
    the old every-union-op-every-round program."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.topology import make_topology_schedule
    from repro.core.trainer import ExactConsensus
    from repro.launch.hlo_cost import analyze_compiled
    from repro.launch.sharding import node_shardings

    repl = NamedSharding(mesh, P())
    sched = make_topology_schedule("matching:4", M)
    ec = ExactConsensus(sched, backend="ppermute", mesh=mesh)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (M, d))}
    stree = lambda t: node_shardings(t, mesh, M)

    def fn(t, step):
        out, _ = ec.mix(t, (), None, None, step=step)
        return out

    compiled = (
        jax.jit(fn, in_shardings=(stree(theta), repl))
        .lower(theta, jnp.int32(1))
        .compile()
    )
    cost = analyze_compiled(compiled)
    cp = cost.coll["collective-permute"]
    # busiest single phase: a one-peer matching moves 1 dense f32 model per
    # node; the union across 4 phases would move up to 4
    phase_edges = max(sched.topology_at(p).max_degree for p in range(sched.period))
    union_edges = ec.union.max_out_degree
    expect = phase_edges * 4.0 * d
    rows = [{
        "table": "X",
        "scenario": "exact_round_sched_phase",
        "topology": "matching:4",
        "compressor": "identity",
        "backend": "ppermute",
        "d": d,
        "coll_permute_bytes": cp,
        "all_gather_bytes": cost.coll["all-gather"],
        "coll_operand_bytes": cost.coll_bytes,
        "wire_bytes": cost.wire_bytes(M),
        "expected_wire_bytes": expect,
        "per_edge_bytes": cp / phase_edges,
        "per_edge_payload_bytes": 4.0 * d,
        "union_edges": float(union_edges),
    }]
    assert cost.coll["all-gather"] == 0.0, (
        f"exact_round_sched_phase emitted all-gather bytes "
        f"({cost.coll['all-gather']:.0f})"
    )
    assert cp <= 1.3 * expect, (
        f"exact_round_sched_phase: collective-permute bytes {cp:.0f} not ~ "
        f"busiest-phase degree x f32 model ({expect:.0f}) — the per-phase "
        f"wire program regressed to the whole union ({union_edges} edges)"
    )
    return rows


def _masked_round_rows(mesh, d: int, quick: bool) -> list[dict]:
    """Time-varying rounds on the hat-delta wire: masked/scheduled ppermute
    rounds must move compressed-payload bytes per union edge — NOT the f32
    ``theta_hat`` public copies the pre-NeighborCache implementation shipped
    (32 bits/element vs ~5 for kq4b: an ~6x regression if it ever comes
    back).  Per-edge bytes are asserted <= 1.1x the static compressed
    payload (the ISSUE-5 acceptance bar)."""
    import jax
    import jax.numpy as jnp

    from repro.core import gossip
    from repro.core.compression import make_compressor
    from repro.core.topology import compile_schedule_plans, make_topology_schedule
    from repro.core.wire import compile_union_wire
    from repro.launch.hlo_cost import analyze_compiled
    from repro.launch.sharding import node_shardings
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())
    scenarios = [
        ("masked-ring", "ring", 0.2, "kq4b"),
        ("sched-rr", "roundrobin:ring,torus", 0.0, "kq4b"),
        ("masked-rr", "roundrobin:ring,torus", 0.2, "kq4b"),
    ]
    if not quick:
        scenarios += [("masked-matching", "matching:4", 0.2, "kq4b"),
                      ("masked-ring-q4b", "ring", 0.2, "q4b")]

    rows = []
    for sname, spec, dropout, cspec in scenarios:
        sched = make_topology_schedule(spec, M, dropout=dropout)
        union = compile_union_wire(compile_schedule_plans(sched))
        comp = make_compressor(cspec)
        theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (M, d))}
        state = gossip.choco_init(theta, cache_ops=union.n_ops)
        key = jax.random.PRNGKey(1)
        topo0 = sched.topology_at(0)
        masked = dropout > 0.0
        stree = lambda t: node_shardings(t, mesh, M)

        def fn(t, s, k, step, mask=None):
            return gossip.choco_round(
                t, s, topo0, 0.2, comp, k, mask=mask, backend="ppermute",
                mesh=mesh, schedule=sched, step=step,
            )

        args = [theta, state, key, jnp.int32(1)]
        shards = [stree(theta), stree(state), repl, repl]
        if masked:
            args.append(jnp.ones((M,), jnp.float32))
            shards.append(stree(args[-1]))
        compiled = (
            jax.jit(fn, in_shardings=tuple(shards))
            .lower(*args)
            .compile()
        )
        cost = analyze_compiled(compiled)
        cp = cost.coll["collective-permute"]
        edges = union.max_out_degree
        payload = _payload_bytes(cspec, d)
        # alive + degree participation floats ride each union exchange when
        # masked (two [block]-float messages per op — noise vs the payload)
        overhead = 8.0 * union.n_ops if masked else 0.0
        expect = edges * payload + overhead
        rows.append({
            "table": "X",
            "scenario": f"choco_round_{sname}",
            "topology": spec,
            "compressor": cspec,
            "backend": "ppermute",
            "d": d,
            "coll_permute_bytes": cp,
            "all_gather_bytes": cost.coll["all-gather"],
            "coll_operand_bytes": cost.coll_bytes,
            "wire_bytes": cost.wire_bytes(M),
            "expected_wire_bytes": expect,
            "per_edge_bytes": cp / edges,
            "per_edge_payload_bytes": payload,
        })
        assert cost.coll["all-gather"] == 0.0, (
            f"{sname}: masked/scheduled ppermute round emitted all-gather "
            f"bytes ({cost.coll['all-gather']:.0f})"
        )
        assert 0.9 * expect <= cp <= 1.6 * expect, (
            f"{sname}: collective-permute bytes {cp:.0f} not ~ union-degree x "
            f"compressed payload ({expect:.0f}) — f32 hat exchange regression?"
        )
        assert cp / edges <= 1.1 * payload, (
            f"{sname}: per-edge bytes {cp / edges:.0f} exceed 1.1x the static "
            f"compressed payload ({payload:.0f})"
        )
    return rows


def _baseline_rows(mesh, d: int) -> list[dict]:
    """Wire-honest baselines: the full DR-DSGD (ExactConsensus) and DRFA
    (FedAvg) train steps compile under backend='ppermute' with zero
    all-gather — DR-DSGD moves dense f32 models between ring neighbors via
    collective-permute (that IS its algorithmic wire), DRFA aggregates with
    one psum (ring all-reduce) and no permutes."""
    import jax
    import jax.numpy as jnp

    from repro.core.baselines import (
        DRDSGDConfig, DRFAConfig, drdsgd_trainer, drfa_trainer,
    )
    from repro.launch.hlo_cost import analyze_compiled
    from repro.launch.sharding import node_shardings

    def loss_fn(params, batch, rng):
        return (batch @ params["w"]).mean()

    params = {"w": jnp.zeros((d,))}
    rows = []

    # ---- DR-DSGD: exact (dense f32) neighbor gossip over the ring --------
    for backend in ("rolled", "ppermute"):
        cfg = DRDSGDConfig(num_nodes=M, topology="ring", eta_theta=0.1,
                           gossip_backend=backend, track_average=False)
        trainer = drdsgd_trainer(
            cfg, loss_fn, mesh=mesh if backend == "ppermute" else None
        )
        batch = jax.random.normal(jax.random.PRNGKey(2), (M, 4, d))
        state = jax.eval_shape(trainer.init, params, jax.random.PRNGKey(0))
        spec = node_shardings(state, mesh, M)
        compiled = (
            jax.jit(trainer.step_impl,
                    in_shardings=(spec, node_shardings(batch, mesh, M)))
            .lower(state, jax.ShapeDtypeStruct(batch.shape, batch.dtype))
            .compile()
        )
        cost = analyze_compiled(compiled)
        expect = 2 * 4.0 * d  # degree x dense f32 model
        rows.append({
            "table": "X", "scenario": "drdsgd_step", "topology": "ring",
            "compressor": "identity", "backend": backend, "d": d,
            "coll_permute_bytes": cost.coll["collective-permute"],
            "all_gather_bytes": cost.coll["all-gather"],
            "coll_operand_bytes": cost.coll_bytes,
            "wire_bytes": cost.wire_bytes(M),
            "expected_wire_bytes": expect,
        })
        if backend == "ppermute":
            cp = cost.coll["collective-permute"]
            assert cost.coll["all-gather"] == 0.0, (
                f"drdsgd ppermute step emitted all-gather bytes "
                f"({cost.coll['all-gather']:.0f})"
            )
            assert 0.9 * expect <= cp <= 1.3 * expect, (
                f"drdsgd ppermute collective-permute bytes {cp:.0f} not ~ "
                f"degree x f32 model ({expect:.0f})"
            )

    # ---- DRFA: server averaging as one psum ------------------------------
    K = 2
    for backend in ("rolled", "ppermute"):
        cfg = DRFAConfig(num_nodes=M, local_steps=K, eta_theta=0.1,
                         gossip_backend=backend, track_average=False)
        trainer = drfa_trainer(
            cfg, loss_fn, mesh=mesh if backend == "ppermute" else None
        )
        batch = jax.random.normal(jax.random.PRNGKey(3), (M, K, 4, d))
        state = jax.eval_shape(trainer.init, params, jax.random.PRNGKey(0))
        spec = node_shardings(state, mesh, M)
        compiled = (
            jax.jit(trainer.step_impl,
                    in_shardings=(spec, node_shardings(batch, mesh, M)))
            .lower(state, jax.ShapeDtypeStruct(batch.shape, batch.dtype))
            .compile()
        )
        cost = analyze_compiled(compiled)
        expect = 4.0 * d  # one model-sized all-reduce operand
        rows.append({
            "table": "X", "scenario": "drfa_step", "topology": "star",
            "compressor": "identity", "backend": backend, "d": d,
            "coll_permute_bytes": cost.coll["collective-permute"],
            "all_gather_bytes": cost.coll["all-gather"],
            "coll_operand_bytes": cost.coll_bytes,
            "wire_bytes": cost.wire_bytes(M),
            "expected_wire_bytes": expect,
        })
        if backend == "ppermute":
            ar = cost.coll["all-reduce"]
            # the dual ascent combines the node-sharded [m] loss vector with
            # the replicated lambda — GSPMD gathers those m floats.  That is
            # dual traffic (already billed: DRFA's lambda exchange), not a
            # model-wire leak; anything above one m-float vector fails.
            assert cost.coll["all-gather"] <= 4.0 * M, (
                f"drfa ppermute step emitted model-scale all-gather bytes "
                f"({cost.coll['all-gather']:.0f})"
            )
            assert 0.9 * expect <= ar <= 1.3 * expect, (
                f"drfa ppermute all-reduce bytes {ar:.0f} not ~ one f32 "
                f"model ({expect:.0f})"
            )
    return rows


def _train_step_rows(mesh, d: int) -> list[dict]:
    """Compile the *full* AD-GDA train step (oracle + dual + consensus) on
    both backends: the ppermute step's collective-permute bytes must still be
    dominated by degree x payload (model payload + the m-float lambda gossip
    riding the same permutes)."""
    import jax
    import jax.numpy as jnp

    from repro.core import ADGDAConfig, adgda_trainer
    from repro.launch.hlo_cost import analyze_compiled
    from repro.launch.sharding import node_shardings

    def loss_fn(params, batch, rng):
        return (batch @ params["w"]).mean()

    params = {"w": jnp.zeros((d,))}
    batch = jax.random.normal(jax.random.PRNGKey(2), (M, 4, d))

    rows = []
    wire = {}
    for backend in ("rolled", "ppermute"):
        cfg = ADGDAConfig(
            num_nodes=M, topology="ring", compressor="kq4b", alpha=0.05,
            eta_theta=0.1, eta_lambda=0.05, track_average=False,
            gossip_backend=backend,
        )
        trainer = adgda_trainer(
            cfg, loss_fn, mesh=mesh if backend == "ppermute" else None
        )
        state = jax.eval_shape(trainer.init, params, jax.random.PRNGKey(0))
        spec = node_shardings(state, mesh, M)
        compiled = (
            jax.jit(trainer.step_impl, in_shardings=(spec, node_shardings(batch, mesh, M)))
            .lower(state, jax.ShapeDtypeStruct(batch.shape, batch.dtype))
            .compile()
        )
        cost = analyze_compiled(compiled)
        expect = 2 * (_payload_bytes("kq4b", d) + 4.0 * M)  # + lambda row gossip
        wire[backend] = cost.wire_bytes(M)
        rows.append({
            "table": "X",
            "scenario": "train_step",
            "topology": "ring",
            "compressor": "kq4b",
            "backend": backend,
            "d": d,
            "coll_permute_bytes": cost.coll["collective-permute"],
            "all_gather_bytes": cost.coll["all-gather"],
            "coll_operand_bytes": cost.coll_bytes,
            "wire_bytes": wire[backend],
            "expected_wire_bytes": expect,
        })
        if backend == "ppermute":
            cp = cost.coll["collective-permute"]
            assert 0.9 * expect <= cp <= 2.0 * expect, (
                f"train_step ppermute collective-permute bytes {cp:.0f} not ~ "
                f"degree x (payload + lambda) ({expect:.0f})"
            )
    assert wire["ppermute"] < wire["rolled"], (
        f"train_step: ppermute wire bytes {wire['ppermute']:.0f} not strictly "
        f"below rolled {wire['rolled']:.0f}"
    )
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        out = _child(quick="--full" not in sys.argv)
        print(_MARK + json.dumps(out))
    else:
        from benchmarks.common import print_rows

        print_rows(run(quick="--full" not in sys.argv))
