"""Gossip-round benchmark — fused vs packed vs unpacked CHOCO paths.

Times one complete ``choco_round`` (jitted, state donated semantics aside)
per {compressor x topology x d} for the three dispatch paths:

  fused     single-pass Pallas kernels (kernels/choco_fused.py)
  packed    encode once, roll the packed payload, dequantize per shift
  unpacked  decode first, mix dense f32 (the numerics oracle)

On CPU the kernels run in interpret mode, so absolute numbers are indicative
only, but the *ratio* tracks the eliminated full-tensor passes — the fused
path must stay ahead of packed (the acceptance bar is >=1.5x at d >= 2^20).
``benchmarks.run`` persists these rows to BENCH_G.json so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.topology import make_topology
from repro.kernels.ops import KernelQuantization

M = 8  # nodes; ring degree 2, torus degree 4


def _time_round(topo, comp, theta, state, key, reps, **round_kw):
    fn = jax.jit(
        lambda t, s, k: gossip.choco_round(t, s, topo, 0.2, comp, k, **round_kw)
    )
    jax.block_until_ready(fn(theta, state, key))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(theta, state, key))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def run(quick: bool = True) -> list[dict]:
    rows = []
    # the acceptance bar lives at d >= 2^20, so quick mode still measures it
    ds = [1 << 14, 1 << 20] if quick else [1 << 14, 1 << 17, 1 << 20, 1 << 22]
    reps = 3 if quick else 5
    paths = {
        "fused": dict(fused=True),
        "packed": dict(packed=True),
        "unpacked": dict(packed=False),
    }
    for bits in (8, 4):
        comp = KernelQuantization(bits=bits)
        for topo_name in ("ring", "torus"):
            topo = make_topology(topo_name, M)
            for d in ds:
                theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (M, d))}
                state = gossip.choco_init(theta)
                key = jax.random.PRNGKey(1)
                ms = {
                    name: _time_round(topo, comp, theta, state, key, reps, **kw)
                    for name, kw in paths.items()
                }
                rows.append({
                    "table": "G",
                    "compressor": f"kq{bits}b",
                    "topology": topo_name,
                    "d": d,
                    "ms_fused": ms["fused"],
                    "ms_packed": ms["packed"],
                    "ms_unpacked": ms["unpacked"],
                    "speedup_fused_vs_packed": ms["packed"] / ms["fused"],
                })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
