"""Fault-tolerance suite — worst-node accuracy and consensus error under
time-varying topologies and Bernoulli node dropout (ISSUE 3 tentpole).

Scenario grid: wire schedule (static ring / round-robin ring+torus / random
one-peer matchings) x per-round dropout rate.  Validates the failure-mode
story end-to-end: the masked Metropolis rescale keeps W(t) doubly stochastic
on the surviving subgraph, dropped nodes rejoin without resetting CHOCO
trackers, and robustness (worst-node accuracy) degrades gracefully — not
catastrophically — as participation drops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_adgda, train_trainer, worst_avg
from repro.data import rotated_minority_classification


def _consensus_err(theta_stacked) -> float:
    err = 0.0
    for leaf in jax.tree_util.tree_leaves(theta_stacked):
        leaf = np.asarray(leaf, np.float32)
        err += float(((leaf - leaf.mean(0)) ** 2).sum())
    return err


def run(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    m = 10
    steps = 400 if quick else 2000
    schedules = [
        ("static-ring", {"topology": "ring"}),
        ("rr-ring-torus", {"topology_schedule": "roundrobin:ring,torus"}),
        ("matching", {"topology_schedule": "matching:8"}),
    ]
    rows = []
    for sched_name, sched_kw in schedules:
        for dropout in (0.0, 0.1, 0.3):
            worst_accs, cons_errs, realized = [], [], []
            for seed in seeds:
                data = rotated_minority_classification(num_nodes=m, seed=seed)
                trainer, init_fn, apply_fn = make_adgda(
                    "logistic", m, compressor="q4b", dropout=dropout, **sched_kw,
                )
                params, info = train_trainer(
                    trainer, init_fn(data.dim, data.num_classes), data, steps,
                    batch=50, seed=seed,
                )
                w, _ = worst_avg(apply_fn, params, data)
                worst_accs.append(w)
                cons_errs.append(_consensus_err(info["state"].theta))
                realized.append(info["bits_per_round_realized"])
            rows.append({
                "table": "FT",
                "schedule": sched_name,
                "dropout": dropout,
                "steps": steps,
                "worst_acc": sum(worst_accs) / len(worst_accs),
                "consensus_err": sum(cons_errs) / len(cons_errs),
                # upper bound (busiest phase, everyone alive), the
                # participation-aware expectation, and the run's MEASURED
                # traffic from the jitted realized-bits meter (the per-round
                # busiest-node realization — lands between the expectation
                # and the bound; the gap to the bound is the dropout
                # dividend)
                "bits_per_round": info["bits_per_round"],
                "bits_per_round_expected": float(
                    trainer.bits_per_round(info["state"], mode="expected")
                ),
                "bits_per_round_realized": sum(realized) / len(realized),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
