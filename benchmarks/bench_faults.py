"""Fault-tolerance suite — worst-node accuracy and consensus error under
time-varying topologies, Bernoulli node dropout, and injected wire faults
(ISSUE 3 tentpole; fault axis from ISSUE 6).

Scenario grid: wire schedule (static ring / round-robin ring+torus / random
one-peer matchings) x per-round node-dropout rate x wire fault spec.
Validates the failure-mode story end-to-end: the masked Metropolis rescale
keeps W(t) doubly stochastic on the surviving subgraph, dropped nodes rejoin
without resetting CHOCO trackers, digests catch every silently diverged
mirror and the staleness-bounded resync heals it, and robustness
(worst-node accuracy) degrades gracefully — not catastrophically — as
participation drops or messages are lost.

Key naming is shared verbatim by the persisted BENCH_FT.json rows, the
printed table, the check_regression.py FT gate, and the README fault table:
``dropout`` is the announced node-dropout probability, ``fault_spec`` is the
wire-fault spec string ("none" when faults are off), ``faults_detected`` /
``resyncs`` are the run's network-total digest detections and dense resyncs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_adgda, train_trainer, worst_avg
from repro.data import rotated_minority_classification


def _consensus_err(theta_stacked) -> float:
    err = 0.0
    for leaf in jax.tree_util.tree_leaves(theta_stacked):
        leaf = np.asarray(leaf, np.float32)
        err += float(((leaf - leaf.mean(0)) ** 2).sum())
    return err


def _fault_telemetry(state) -> tuple[float, float]:
    """Network-total (digest detections, dense resyncs) — 0.0 when unfaulted.
    Gradient-tracking state carries one fault machine per wire lane; the
    network total sums both lanes."""
    cons = state.consensus
    if hasattr(cons, "model") and hasattr(cons, "tracker"):
        lanes = (cons.model, cons.tracker)
    else:
        lanes = (cons,)
    det = res = 0.0
    for lane in lanes:
        fault = getattr(lane, "fault", None)
        if fault is None or not hasattr(fault, "detected"):
            continue
        det += float(np.asarray(fault.detected).sum())
        res += float(np.asarray(fault.resyncs).sum())
    return det, res


def run(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    m = 10
    steps = 400 if quick else 2000
    schedules = [
        ("static-ring", {"topology": "ring"}),
        ("rr-ring-torus", {"topology_schedule": "roundrobin:ring,torus"}),
        ("matching", {"topology_schedule": "matching:8"}),
    ]
    # (dropout, fault_spec) axes: the announced-dropout sweep stays
    # fault-free; the wire-fault sweep runs on the full graph so the FT gate
    # can band each faulted row against its fault-free twin.
    scenarios = [(d, "none") for d in (0.0, 0.1, 0.3)]
    scenarios += [(0.0, "drop:0.1,stale:2"), (0.0, "corrupt:0.05,stale:2")]
    rows = []
    for sched_name, sched_kw in schedules:
        for dropout, fault_spec in scenarios:
            kw = dict(sched_kw)
            if fault_spec != "none":
                kw["fault_spec"] = fault_spec
            worst_accs, cons_errs, realized = [], [], []
            detected, resyncs = [], []
            for seed in seeds:
                data = rotated_minority_classification(num_nodes=m, seed=seed)
                trainer, init_fn, apply_fn = make_adgda(
                    "logistic", m, compressor="q4b", dropout=dropout, **kw,
                )
                params, info = train_trainer(
                    trainer, init_fn(data.dim, data.num_classes), data, steps,
                    batch=50, seed=seed,
                )
                w, _ = worst_avg(apply_fn, params, data)
                worst_accs.append(w)
                cons_errs.append(_consensus_err(info["state"].theta))
                realized.append(info["bits_per_round_realized"])
                det, res = _fault_telemetry(info["state"])
                detected.append(det)
                resyncs.append(res)
            rows.append({
                "table": "FT",
                "schedule": sched_name,
                "dropout": dropout,
                "fault_spec": fault_spec,
                "steps": steps,
                "worst_acc": sum(worst_accs) / len(worst_accs),
                "consensus_err": sum(cons_errs) / len(cons_errs),
                "faults_detected": sum(detected) / len(detected),
                "resyncs": sum(resyncs) / len(resyncs),
                # upper bound (busiest phase, everyone alive), the
                # participation-aware expectation, and the run's MEASURED
                # traffic from the jitted realized-bits meter (the per-round
                # busiest-node realization — lands between the expectation
                # and the bound on masked rounds; under faults it also
                # carries the digest lane and any dense resync payloads)
                "bits_per_round": info["bits_per_round"],
                "bits_per_round_expected": float(
                    trainer.bits_per_round(info["state"], mode="expected")
                ),
                "bits_per_round_realized": sum(realized) / len(realized),
            })
    rows += run_ksweep(quick=quick, seeds=seeds)
    return rows


def run_ksweep(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    """Local-steps sweep: worst-node accuracy vs realized bits for
    ``consensus in {choco, gt}`` x ``K in {1, 4, 8, 16, 64}`` on the
    heterogeneity benchmark, at a fixed *iteration* budget (rounds = iters/K
    so every cell sees the same number of gradient steps).

    Equal-realized-bits anchor: gt bills two lanes per round, so
    ``gt @ K=16`` and ``choco @ K=8`` move the same total bits over the run —
    that pair is what the check_regression FT invariant compares (gradient
    tracking must convert its second lane into worst-node accuracy, not just
    spend it).  Rows keep the FT table schema (schedule "ksweep-ring",
    fault-free, dropout 0) so the regression gate's clean-twin machinery
    ignores them while the named invariant picks them up via the
    ``consensus``/``local_steps`` keys.
    """
    m = 10
    iters = 800 if quick else 4000
    rows = []
    # the extra cell: gt with a COARSER tracker lane (q2b beside the q4b
    # model lane) at the gt-vs-choco anchor K — same drift correction,
    # ~25% fewer per-round bits than two q4b lanes.  The row carries a
    # tracker_compressor key so the equal-bits ksweep invariant (which
    # reasons about 2x-lane gt rows) skips it.
    cells = ([(c, k, None) for c in ("choco", "gt") for k in (1, 4, 8, 16, 64)]
             + [("gt", 16, "q2b")])
    for consensus, k, tracker_comp in cells:
        rounds = max(1, iters // k)
        worst_accs, realized, totals = [], [], []
        for seed in seeds:
            data = rotated_minority_classification(num_nodes=m, seed=seed)
            trainer, init_fn, apply_fn = make_adgda(
                "logistic", m, compressor="q4b", consensus=consensus,
                local_steps=k, tracker_compressor=tracker_comp,
            )
            params, info = train_trainer(
                trainer, init_fn(data.dim, data.num_classes), data,
                rounds, batch=50 * k, seed=seed,
            )
            w, _ = worst_avg(apply_fn, params, data)
            worst_accs.append(w)
            realized.append(info["bits_per_round_realized"])
            totals.append(info.get("bits_realized_total",
                                   info["total_bits"]))
        row = {
            "table": "FT",
            "schedule": "ksweep-ring",
            "dropout": 0.0,
            "fault_spec": "none",
            "consensus": consensus,
            "local_steps": k,
            "steps": rounds,
            "worst_acc": sum(worst_accs) / len(worst_accs),
            "bits_per_round_realized": sum(realized) / len(realized),
            # total wire traffic over the run and the equal-footing
            # per-local-iteration rate (two-lane gt cost divided by K)
            "bits_total_realized": sum(totals) / len(totals),
            "bits_per_iteration": float(
                trainer.bits_per_round(info["state"], per_iteration=True)
            ),
        }
        if tracker_comp is not None:
            row["tracker_compressor"] = tracker_comp
        rows.append(row)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
