"""Paper Table 5 + Figure 5 — AD-GDA vs DRFA vs DR-DSGD vs CHOCO-SGD:
final worst-case accuracy and communication efficiency (bits transmitted by
the busiest node to reach a target worst-node accuracy).

Validates: AD-GDA attains the highest worst-node accuracy and reaches any
fixed accuracy with a fraction of the bits (paper: 3-10x fewer).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import MODELS, make_adgda, make_loss, train_trainer, worst_avg
from repro.core import DRDSGDConfig, DRFAConfig, drdsgd_trainer, drfa_trainer
from repro.data import (
    contrast_shift_classification,
    instrument_shift_classification,
    rotated_minority_classification,
)

SETUPS = {
    "rotated_minority": lambda seed: rotated_minority_classification(num_nodes=10, seed=seed),
    "cifar_analog": lambda seed: contrast_shift_classification(num_nodes=10, dim=24, seed=seed),
    "coos7_analog": lambda seed: instrument_shift_classification(num_nodes=10, dim=24, seed=seed),
}


def _train_drdsgd(data, steps, seed):
    init_fn, apply_fn = MODELS["logistic"]
    tr = drdsgd_trainer(
        DRDSGDConfig(num_nodes=data.num_nodes, topology="torus", alpha=6.0,
                     eta_theta=0.3, lr_decay=0.99),
        make_loss(apply_fn),
    )
    state = tr.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(seed))
    gen = data.batches(50, seed=seed)
    bits = float(tr.bits_per_round(state))
    curve = []
    for t in range(steps):
        xb, yb = next(gen)
        state, aux = tr.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
        curve.append((t, float(aux["worst_loss"]), (t + 1) * bits))
    return tr.network_mean(state), {"total_bits": bits * steps, "curve": curve}, apply_fn


def _train_drfa(data, steps, seed, local_steps=10):
    init_fn, apply_fn = MODELS["logistic"]
    tr = drfa_trainer(
        DRFAConfig(num_nodes=data.num_nodes, participation=0.5, local_steps=local_steps,
                   eta_theta=0.3, eta_lambda=0.1, lr_decay=0.99),
        make_loss(apply_fn),
    )
    state = tr.init(init_fn(data.dim, data.num_classes), jax.random.PRNGKey(seed))
    gen = data.batches(50 * local_steps, seed=seed)
    rounds = max(1, steps // local_steps)
    # per-iteration bits put DRFA's K-local-step rounds on the same x-axis as
    # the per-iteration algorithms (one DRFA round = K gradient iterations)
    bits_iter = float(tr.bits_per_round(state, per_iteration=True))
    curve = []
    m = data.num_nodes
    for t in range(rounds):
        xb, yb = next(gen)
        xb = xb.reshape(m, local_steps, -1, data.dim)
        yb = yb.reshape(m, local_steps, -1)
        state, aux = tr.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
        iters = (t + 1) * local_steps
        curve.append((t * local_steps, float(aux["worst_loss"]), iters * bits_iter))
    return tr.network_mean(state), {
        "total_bits": bits_iter * local_steps * rounds, "curve": curve,
    }, apply_fn


def run(quick: bool = True, seeds=(0, 1)) -> list[dict]:
    steps = 600 if quick else 5000
    rows = []
    for setup, make_data in SETUPS.items():
        per_algo: dict[str, list] = {}
        for seed in seeds:
            data = make_data(seed)

            # AD-GDA (compressed, chi2), AD-GDA-K5 (5 local steps between
            # gossip rounds — paper §6 extension), AD-GDA-GT-K5 (same K but
            # gradient tracking: the tracker lane doubles the per-round bits
            # — bits_per_round(per_iteration=True) spreads the two-lane cost
            # over the K iterations so the x-axis stays honest) and CHOCO-SGD
            for robust, name, k, cons in (
                (True, "AD-GDA", 1, "choco"),
                (True, "AD-GDA-K5", 5, "choco"),
                (True, "AD-GDA-GT-K5", 5, "gt"),
                (False, "CHOCO-SGD", 1, "choco"),
            ):
                trainer, init_fn, apply_fn = make_adgda(
                    "logistic", data.num_nodes, robust=robust,
                    compressor="q4b", topology="torus", local_steps=k,
                    consensus=cons,
                )
                params, info = train_trainer(
                    trainer, init_fn(data.dim, data.num_classes), data,
                    steps // k, batch=50 * k, seed=seed, track_worst_loss=True,
                )
                w, a = worst_avg(apply_fn, params, data)
                per_algo.setdefault(name, []).append((w, info["total_bits"]))

            p, info, apply_fn = _train_drdsgd(data, steps, seed)
            w, _ = worst_avg(apply_fn, p, data)
            per_algo.setdefault("DR-DSGD", []).append((w, info["total_bits"]))

            p, info, apply_fn = _train_drfa(data, steps, seed)
            w, _ = worst_avg(apply_fn, p, data)
            per_algo.setdefault("DRFA", []).append((w, info["total_bits"]))

        for name, vals in per_algo.items():
            ws = [v[0] for v in vals]
            bits = [v[1] for v in vals]
            rows.append({
                "table": "T5",
                "setup": setup,
                "algo": name,
                "worst_acc": float(np.mean(ws)),
                "gbits_total": float(np.mean(bits)) / 1e9,
            })

        # communication-efficiency ratio at matched accuracy (Fig. 5):
        adgda_w = float(np.mean([v[0] for v in per_algo["AD-GDA"]]))
        adgda_b = float(np.mean([v[1] for v in per_algo["AD-GDA"]]))
        for other in ("DR-DSGD", "DRFA"):
            ob = float(np.mean([v[1] for v in per_algo[other]]))
            rows.append({
                "table": "F5",
                "setup": setup,
                "algo": f"AD-GDA_vs_{other}",
                "worst_acc": adgda_w - float(np.mean([v[0] for v in per_algo[other]])),
                "gbits_total": ob / max(adgda_b, 1e-9),  # bits ratio (x more efficient)
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
