"""Suite S — decentralized serving fleet: latency/SLO vs offered load, and
the train-and-serve loop (ISSUE 7 tentpole).

Two row kinds, one shared key vocabulary (persisted verbatim to
``BENCH_S.json``, printed by ``benchmarks.run``, gated by
``check_regression.py --suite S``, documented in the README "Serving
fleet" section):

* ``kind="latency"`` — a latency-vs-offered-load curve per fleet config
  (``fleet`` names the shape, e.g. ``m2s2`` = 2 nodes x 2 slots).  ``rate``
  is the per-node offered load in requests/tick (a scenario axis, kept in
  the row key), ``util`` the analytic utilization ``rate x
  mean_request_tokens / slots``.  Tick-denominated latency percentiles
  (``p50/p95/p99_ttft_ticks``) are bit-deterministic given the loadgen
  seed — the gateable SLO — while wall metrics (``tok_per_s``,
  ``per_token_ms``, ``p50/p99_ttft_ms``) are reported for trend only.
  ``knee_rate`` is the measured latency knee: the largest tested rate whose
  p99 TTFT stays within ``KNEE_INFLATION`` x max(p50, 1) ticks.  The
  admission queue bound (``max_queue = QUEUE_SLOTS_FACTOR x slots``) is
  sized so that below the knee nothing is ever rejected (the SLO
  ``check_regression`` re-asserts baseline-free) while overload sheds
  instead of queueing unboundedly.

* ``kind="train_serve"`` — the DRO guarantee as a serving SLO: a
  decentralized training run (AD-GDA vs its unweighted ``robust=False``
  twin, same seed/topology/compression) checkpoints the consensus model
  every phase through the atomic ``repro.checkpoint`` machinery; a fleet of
  per-node ``ClassifierEngine``s hot-reloads each checkpoint
  (``HotReloader`` — torn files can never be served) while serving
  Poisson traffic drawn from each node's LOCAL distribution.
  ``worst_node_acc`` / ``worst_node_loss`` are the worst per-node-population
  quality probes after the final reload, ``served_worst_acc`` the worst
  per-node accuracy on requests actually served in the final window, and
  ``first_worst_acc`` the probe after the first reload (the across-reloads
  trajectory).  The acceptance bar: the AD-GDA row's ``worst_node_acc``
  beats the unweighted row's.
"""
from __future__ import annotations

import dataclasses
import tempfile

import jax
import numpy as np

from benchmarks.common import make_adgda, make_loss
from repro.checkpoint import save
from repro.data import rotated_minority_classification

# latency-knee definition and admission sizing, shared with check_regression
KNEE_INFLATION = 8.0        # below the knee: p99_ttft <= 8 x max(p50_ttft, 1) ticks
QUEUE_SLOTS_FACTOR = 6      # max_queue = 6 x slots (~ knee-load p99 queue depth)

# fleet shapes for the latency curve: (num_nodes, slots per node)
FLEETS = {"m2s2": (2, 2), "m1s4": (1, 4)}
# offered load as a fraction of per-node capacity slots/mean_request_tokens
UTILIZATIONS = (0.4, 0.8, 1.4)


def _serve_cfg():
    from repro.configs import get_config

    # full attention: the reduced configs' 16-token sliding window would
    # force exact-length prefill (ring wrap) and defeat prompt bucketing
    return dataclasses.replace(
        get_config("qwen3-1.7b").reduced(), long_context_window=None
    )


def _latency_rows(quick: bool) -> list[dict]:
    import jax as _jax

    from repro.models import transformer as T
    from repro.serving import (
        AdmissionControl,
        FleetNode,
        LoadGenConfig,
        LoadGenerator,
        ServeEngine,
        ServingFleet,
    )

    cfg = _serve_cfg()
    params = T.init_model(_jax.random.PRNGKey(0), cfg)
    n_requests = 170 if quick else 4000
    rows = []
    for fleet_name, (m, slots) in FLEETS.items():
        lg_probe = LoadGenConfig(num_nodes=m, rate=1.0, vocab_size=cfg.vocab_size,
                                 prompt_min=4, prompt_max=24,
                                 output_min=1, output_max=8, seed=0)
        capacity = slots / lg_probe.mean_request_tokens()  # requests/tick/node
        fleet_rows = []
        for util in UTILIZATIONS:
            rate = round(util * capacity, 4)
            gen = LoadGenerator(dataclasses.replace(lg_probe, rate=rate))
            nodes = [
                FleetNode(
                    i,
                    ServeEngine(cfg, params, max_slots=slots, cache_len=48,
                                prompt_bucket=8),
                    admission=AdmissionControl(
                        max_queue=QUEUE_SLOTS_FACTOR * slots, policy="reject"
                    ),
                )
                for i in range(m)
            ]
            rep = ServingFleet(nodes, gen).run(
                max_requests=n_requests, max_ticks=200_000
            )
            f = rep.fleet
            fleet_rows.append({
                "table": "S",
                "kind": "latency",
                "fleet": fleet_name,
                "rate": rate,
                "util": round(util, 4),
                "requests": rep.offered,
                "completed": f["completed"],
                "rejected": f["rejected"],
                "shed": f["shed"],
                "ticks": rep.ticks,
                "p50_ttft_ticks": f["p50_ttft_ticks"],
                "p95_ttft_ticks": f["p95_ttft_ticks"],
                "p99_ttft_ticks": f["p99_ttft_ticks"],
                "p50_ttft_ms": f["p50_ttft_ms"],
                "p99_ttft_ms": f["p99_ttft_ms"],
                "per_token_ms": f["per_token_ms"],
                "tok_per_s": f["tok_per_s"],
                "mean_queue_depth": f["mean_queue_depth"],
                "max_queue_depth": f["max_queue_depth"],
                "slot_occupancy": f["slot_occupancy"],
            })
        # measured knee: largest tested rate still inside the inflation SLO
        under = [r for r in fleet_rows
                 if r["p99_ttft_ticks"] <= KNEE_INFLATION * max(r["p50_ttft_ticks"], 1.0)]
        knee = max((r["rate"] for r in under), default=min(r["rate"] for r in fleet_rows))
        for r in fleet_rows:
            r["knee_rate"] = knee
        rows += fleet_rows
    return rows


def _train_serve_rows(quick: bool) -> list[dict]:
    import jax.numpy as jnp

    from benchmarks.common import MODELS
    from repro.serving import (
        AdmissionControl,
        ClassifierEngine,
        EvalRequest,
        FleetNode,
        HotReloader,
        LoadGenConfig,
        LoadGenerator,
        ServingFleet,
    )

    m = 10
    minority_nodes = 2
    phases, rounds = (4, 100) if quick else (8, 250)
    serve_chunk = 30 * m  # requests per serving window (fleet-wide)
    init_fn, apply_fn = MODELS["logistic"]
    loss_fn = make_loss(apply_fn)

    rows = []
    for algo, robust in (("adgda", True), ("unweighted", False)):
        data = rotated_minority_classification(
            num_nodes=m, minority_nodes=minority_nodes, seed=0
        )
        trainer, _, _ = make_adgda("logistic", m, robust=robust, compressor="q4b")
        params0 = init_fn(data.dim, data.num_classes)
        state = trainer.init(params0, jax.random.PRNGKey(0))
        gen_batches = data.batches(50, seed=0)

        with tempfile.TemporaryDirectory() as tmp:
            prefix = f"{tmp}/consensus_{algo}"

            # ---- the serving side: one classifier engine per node, traffic
            # from the node's local distribution, hot reload + quality probe
            def payload_for(node_data_x, node_data_y):
                n = node_data_x.shape[0]

                def payload(node, rng, plen, max_new):
                    idx = int(rng.integers(0, n))
                    return EvalRequest(
                        features=node_data_x[idx:idx + 1],
                        labels=node_data_y[idx:idx + 1],
                    )

                return payload

            def quality_for(node):
                # node's latent population: minority for the rotated nodes
                dist = 1 if node < minority_nodes else 0
                name_to_idx = {n: i for i, n in enumerate(data.val_names)}
                vi = name_to_idx["minority" if dist else "majority"]
                vx, vy = jnp.asarray(data.val_x[vi]), jnp.asarray(data.val_y[vi])

                def quality(params):
                    logits = apply_fn(params, vx)
                    pred = np.asarray(jnp.argmax(logits, -1))
                    loss = float(loss_fn(params, (vx, vy), None))
                    return {"acc": float((pred == np.asarray(vy)).mean()),
                            "loss": loss}

                return quality

            class _NodePayload:
                """Route each node's traffic through its own data pool."""

                def __init__(self):
                    self.per_node = [payload_for(data.x[i], data.y[i]) for i in range(m)]

                def __call__(self, node, rng, plen, max_new):
                    return self.per_node[node](node, rng, plen, max_new)

            gen = LoadGenerator(
                LoadGenConfig(num_nodes=m, rate=0.8, vocab_size=16, seed=1),
                payload=_NodePayload(),
            )
            nodes = [
                FleetNode(
                    i,
                    ClassifierEngine(apply_fn, params0, max_slots=4),
                    admission=AdmissionControl(max_queue=24),
                    reloader=HotReloader(prefix, params0, log=lambda s: None),
                    quality_fn=quality_for(i),
                )
                for i in range(m)
            ]
            fleet = ServingFleet(nodes, gen, reload_every=1)

            # ---- interleave: train a phase, checkpoint consensus
            # (atomic), serve a traffic window against the fresh weights
            first_probe, window_marks = None, []
            for phase in range(phases):
                for _ in range(rounds):
                    xb, yb = next(gen_batches)
                    state, _ = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
                save(prefix, trainer.network_mean(state), step=(phase + 1) * rounds)
                window_marks.append([len(n.requests) for n in nodes])
                fleet.run(max_requests=fleet.offered + serve_chunk, max_ticks=500_000)
                if first_probe is None:
                    first_probe = [n.quality_timeline[-1][1] for n in nodes]

            reloads = sum(n.reloader.reloads for n in nodes)
            final_probe = [n.quality_timeline[-1][1] for n in nodes]
            served_acc = []
            for node, mark in zip(nodes, window_marks[-1]):
                window = [r for r in node.requests[mark:] if r.status == "done"]
                ok = [int(r.output[0]) == int(r.labels[0]) for r in window]
                served_acc.append(float(np.mean(ok)) if ok else 0.0)
            rows.append({
                "table": "S",
                "kind": "train_serve",
                "fleet": f"m{m}s4",
                "algo": algo,
                "rate": 0.8,
                "requests": fleet.offered,
                "steps": phases * rounds,
                "reloads": reloads,
                "reload_skipped": sum(n.reloader.skipped for n in nodes),
                "first_worst_acc": min(q["acc"] for q in first_probe),
                "worst_node_acc": min(q["acc"] for q in final_probe),
                "mean_node_acc": float(np.mean([q["acc"] for q in final_probe])),
                "worst_node_loss": max(q["loss"] for q in final_probe),
                "served_worst_acc": min(served_acc),
            })
    return rows


def run(quick: bool = True) -> list[dict]:
    return _latency_rows(quick) + _train_serve_rows(quick)


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
