"""Suite S — decentralized serving fleet: latency/SLO vs offered load, and
the train-and-serve loop (ISSUE 7 tentpole).

Two row kinds, one shared key vocabulary (persisted verbatim to
``BENCH_S.json``, printed by ``benchmarks.run``, gated by
``check_regression.py --suite S``, documented in the README "Serving
fleet" section):

* ``kind="latency"`` — a latency-vs-offered-load curve per fleet config
  (``fleet`` names the shape, e.g. ``m2s2`` = 2 nodes x 2 slots).  ``rate``
  is the per-node offered load in requests/tick (a scenario axis, kept in
  the row key), ``util`` the analytic utilization ``rate x
  mean_request_tokens / slots``.  Tick-denominated latency percentiles
  (``p50/p95/p99_ttft_ticks``) are bit-deterministic given the loadgen
  seed — the gateable SLO — while wall metrics (``tok_per_s``,
  ``per_token_ms``, ``p50/p99_ttft_ms``, ``wall``) are reported for trend
  only.  ``knee_rate`` is the measured latency knee: the largest tested rate
  whose p99 TTFT stays within ``KNEE_INFLATION`` x max(p50, 1) ticks.  The
  admission queue bound (``max_queue = QUEUE_SLOTS_FACTOR x slots``) is
  sized so that below the knee nothing is ever rejected (the SLO
  ``check_regression`` re-asserts baseline-free) while overload sheds
  instead of queueing unboundedly.

  Fast-path instrumentation (ISSUE 9): at ``SPEEDUP_UTIL`` each fleet also
  runs a ``fastpath="off"`` twin — the pre-cache engine (per-engine jit,
  batch-1 prefill, no prefix cache) on IDENTICAL traffic.  The twin must
  match the fast row bit-for-bit on every tick-denominated field (the
  correctness gate: the fast path is a wall-clock lever ONLY), and the fast
  row records ``speedup_fastpath = twin.wall / fast.wall`` which
  ``check_regression`` gates at an absolute >= 2x.  ``cache_hit_rate`` /
  ``prefill_skipped`` count prefix-cache reuse.  Rows with a ``prompts``
  key re-run m2s2 at the speedup util under skewed prompt identity:
  ``prompts="zipf"`` draws from a hot pool of ``PROMPT_POOL`` prompts
  (hit rate must clear 0.3), ``prompts="unique"`` makes every prompt
  distinct (hit rate must be exactly 0 — no false sharing).

* ``kind="train_serve"`` — the DRO guarantee as a serving SLO: a
  decentralized training run (AD-GDA vs its unweighted ``robust=False``
  twin, same seed/topology/compression) checkpoints the consensus model
  every phase through the atomic ``repro.checkpoint`` machinery; a fleet of
  per-node ``ClassifierEngine``s hot-reloads each checkpoint
  (``HotReloader`` — torn files can never be served) while serving
  Poisson traffic drawn from each node's LOCAL distribution.
  ``worst_node_acc`` / ``worst_node_loss`` are the worst per-node-population
  quality probes after the final reload, ``served_worst_acc`` the worst
  per-node accuracy on requests actually served in the final window, and
  ``first_worst_acc`` the probe after the first reload (the across-reloads
  trajectory).  The acceptance bar: the AD-GDA row's ``worst_node_acc``
  beats the unweighted row's.

A third row kind lives OUTSIDE the quick/full set: ``run_scale`` (CLI
``--scale``) serves 10^6 offered requests end-to-end and persists the
single ``kind="scale"`` row to ``BENCH_S_SCALE.json`` — see its docstring.
"""
from __future__ import annotations

import dataclasses
import tempfile

import jax
import numpy as np

from benchmarks.common import make_adgda, make_loss
from repro.checkpoint import save
from repro.data import rotated_minority_classification

# latency-knee definition and admission sizing, shared with check_regression
KNEE_INFLATION = 8.0        # below the knee: p99_ttft <= 8 x max(p50_ttft, 1) ticks
QUEUE_SLOTS_FACTOR = 6      # max_queue = 6 x slots (~ knee-load p99 queue depth)

# fleet shapes for the latency curve: (num_nodes, slots per node)
FLEETS = {"m2s2": (2, 2), "m1s4": (1, 4)}
# offered load as a fraction of per-node capacity slots/mean_request_tokens
UTILIZATIONS = (0.4, 0.8, 1.4)
# the util where fast-path twins run (and the speedup_fastpath gate applies)
SPEEDUP_UTIL = 0.8
# hot-prompt pool size for the prompts="zipf" rows
PROMPT_POOL = 64


def _serve_cfg():
    from repro.configs import get_config

    # full attention: the reduced configs' 16-token sliding window would
    # force exact-length prefill (ring wrap) and defeat prompt bucketing
    return dataclasses.replace(
        get_config("qwen3-1.7b").reduced(), long_context_window=None
    )


def _fleet_run(cfg, params, m, slots, rate, n_requests, *, fastpath=True,
               prompt_mode="iid", seed=0, retain="all", progress_every=0):
    """One fleet point: build loadgen + engines, serve, return the report.

    ``fastpath=False`` runs the pre-cache engine (per-engine jit, no prefix
    cache, batch-1 prefill) on IDENTICAL traffic — the twin the suite-S gate
    compares tick-for-tick."""
    from repro.serving import (
        AdmissionControl,
        FleetNode,
        LoadGenConfig,
        LoadGenerator,
        ServeEngine,
        ServingFleet,
    )

    lg = LoadGenConfig(num_nodes=m, rate=rate, vocab_size=cfg.vocab_size,
                       prompt_min=4, prompt_max=24,
                       output_min=1, output_max=8, seed=seed,
                       prompt_mode=prompt_mode, prompt_pool=PROMPT_POOL)
    nodes = [
        FleetNode(
            i,
            ServeEngine(cfg, params, max_slots=slots, cache_len=48,
                        prompt_bucket=8, fastpath=fastpath),
            admission=AdmissionControl(
                max_queue=QUEUE_SLOTS_FACTOR * slots, policy="reject"
            ),
            retain=retain,
        )
        for i in range(m)
    ]
    fleet = ServingFleet(nodes, LoadGenerator(lg),
                         progress_every=progress_every)
    return fleet.run(max_requests=n_requests, max_ticks=200_000_000)


def _latency_row(rep, fleet_name, rate, util) -> dict:
    f = rep.fleet
    return {
        "table": "S",
        "kind": "latency",
        "fleet": fleet_name,
        "rate": rate,
        "util": round(util, 4),
        "requests": rep.offered,
        "completed": f["completed"],
        "rejected": f["rejected"],
        "shed": f["shed"],
        "ticks": rep.ticks,
        "p50_ttft_ticks": f["p50_ttft_ticks"],
        "p95_ttft_ticks": f["p95_ttft_ticks"],
        "p99_ttft_ticks": f["p99_ttft_ticks"],
        "p50_ttft_ms": f["p50_ttft_ms"],
        "p99_ttft_ms": f["p99_ttft_ms"],
        "per_token_ms": f["per_token_ms"],
        "tok_per_s": f["tok_per_s"],
        "mean_queue_depth": f["mean_queue_depth"],
        "max_queue_depth": f["max_queue_depth"],
        "slot_occupancy": f["slot_occupancy"],
        "cache_hit_rate": f["cache_hit_rate"],
        "prefill_skipped": f["prefill_skipped"],
        "wall": rep.wall_seconds,
    }


def _latency_rows(quick: bool) -> list[dict]:
    import jax as _jax

    from repro.models import transformer as T

    cfg = _serve_cfg()
    params = T.init_model(_jax.random.PRNGKey(0), cfg)
    n_requests = 170 if quick else 4000
    rows = []
    for fleet_name, (m, slots) in FLEETS.items():
        lg_probe = LoadGenConfig_probe(cfg, m)
        capacity = slots / lg_probe.mean_request_tokens()  # requests/tick/node
        fleet_rows, twin_rows = [], []
        for util in UTILIZATIONS:
            rate = round(util * capacity, 4)
            rep = _fleet_run(cfg, params, m, slots, rate, n_requests)
            row = _latency_row(rep, fleet_name, rate, util)
            fleet_rows.append(row)
            if util == SPEEDUP_UTIL:
                # the pre-cache twin: identical traffic through the legacy
                # engine.  Tick metrics must match the fast row bitwise
                # (check_regression re-asserts); wall is the claim.
                off = _fleet_run(cfg, params, m, slots, rate, n_requests,
                                 fastpath=False)
                twin = _latency_row(off, fleet_name, rate, util)
                twin["fastpath"] = "off"
                row["speedup_fastpath"] = (
                    off.wall_seconds / max(rep.wall_seconds, 1e-9)
                )
                twin_rows.append(twin)
        # measured knee: largest tested rate still inside the inflation SLO
        under = [r for r in fleet_rows
                 if r["p99_ttft_ticks"] <= KNEE_INFLATION * max(r["p50_ttft_ticks"], 1.0)]
        knee = max((r["rate"] for r in under), default=min(r["rate"] for r in fleet_rows))
        for r in fleet_rows + twin_rows:
            r["knee_rate"] = knee
        rows += fleet_rows + twin_rows
    rows += _prompt_mode_rows(cfg, params, n_requests)
    rows += _decode_kernel_rows(cfg, params, n_requests)
    return rows


def LoadGenConfig_probe(cfg, m):
    from repro.serving import LoadGenConfig

    return LoadGenConfig(num_nodes=m, rate=1.0, vocab_size=cfg.vocab_size,
                         prompt_min=4, prompt_max=24,
                         output_min=1, output_max=8, seed=0)


def _prompt_mode_rows(cfg, params, n_requests) -> list[dict]:
    """Prompt-repetition structure rows (fleet m2s2 @ the speedup util):
    ``prompts="zipf"`` draws from a hot pool of PROMPT_POOL prompts — the
    workload the prefix cache converts into wall-clock (its on-row must
    show ``cache_hit_rate > 0.3``) — and ``prompts="unique"`` guarantees
    distinct prompts, the zero-hit-rate control (``cache_hit_rate == 0``).
    The zipf pair also carries the tick-equality twin."""
    m, slots = FLEETS["m2s2"]
    capacity = slots / LoadGenConfig_probe(cfg, m).mean_request_tokens()
    rate = round(SPEEDUP_UTIL * capacity, 4)
    rows = []
    for prompts, mode in (("zipf", "pool"), ("unique", "unique")):
        rep = _fleet_run(cfg, params, m, slots, rate, n_requests,
                         prompt_mode=mode)
        row = _latency_row(rep, "m2s2", rate, SPEEDUP_UTIL)
        row["prompts"] = prompts
        rows.append(row)
        if prompts == "zipf":
            off = _fleet_run(cfg, params, m, slots, rate, n_requests,
                             fastpath=False, prompt_mode=mode)
            twin = _latency_row(off, "m2s2", rate, SPEEDUP_UTIL)
            twin["prompts"] = prompts
            twin["fastpath"] = "off"
            row["speedup_fastpath"] = (
                off.wall_seconds / max(rep.wall_seconds, 1e-9)
            )
            rows.append(twin)
    # below-knee SLO applies at this util on this fleet; stamp the iid knee
    # convention (rate itself — these rows are their own sweep point)
    for r in rows:
        r["knee_rate"] = rate
    return rows


def _decode_kernel_rows(cfg, params, n_requests) -> list[dict]:
    """ISSUE-10 decode-kernel twin (``kind="decode_kernel"``, not gated):
    identical m1s4 traffic served by the stock f32 engine and by one with
    ``quantized_kv=True`` (int8 KV cache + fused dequant decode).  Logical
    scheduling is token-count-driven, so tick metrics match; the int8 row
    carries ``wall_ratio_f32`` = f32 wall / int8 wall, informational only:
    at this smoke scale (cache_len=48 on CPU) the per-tick quantize-on-store
    overhead dominates and there are no cache bytes worth saving, so the
    ratio sits *below* 1 — the serving-shape win (L=4096, cache read once at
    1/4 bytes) is measured and gated in suite K instead."""
    m, slots = FLEETS["m1s4"]
    capacity = slots / LoadGenConfig_probe(cfg, m).mean_request_tokens()
    rate = round(SPEEDUP_UTIL * capacity, 4)
    qcfg = dataclasses.replace(cfg, quantized_kv=True)
    # warm the process-wide ProgramCache for BOTH configs so the wall ratio
    # compares steady-state decode, not one side's first-compile
    for c in (cfg, qcfg):
        _fleet_run(c, params, m, slots, rate, min(24, n_requests))
    rep_f32 = _fleet_run(cfg, params, m, slots, rate, n_requests)
    rep_int8 = _fleet_run(qcfg, params, m, slots, rate, n_requests)
    rows = []
    for name, rep in (("f32", rep_f32), ("int8", rep_int8)):
        row = _latency_row(rep, "m1s4", rate, SPEEDUP_UTIL)
        row["kind"] = "decode_kernel"
        row["kv_cache"] = name
        row["knee_rate"] = rate
        if name == "int8":
            row["wall_ratio_f32"] = (
                rep_f32.wall_seconds / max(rep.wall_seconds, 1e-9)
            )
        rows.append(row)
    return rows


def _train_serve_rows(quick: bool) -> list[dict]:
    import jax.numpy as jnp

    from benchmarks.common import MODELS
    from repro.serving import (
        AdmissionControl,
        BatchedProbe,
        ClassifierEngine,
        EvalRequest,
        FleetNode,
        HotReloader,
        LoadGenConfig,
        LoadGenerator,
        ServingFleet,
    )

    m = 10
    minority_nodes = 2
    phases, rounds = (4, 100) if quick else (8, 250)
    serve_chunk = 30 * m  # requests per serving window (fleet-wide)
    init_fn, apply_fn = MODELS["logistic"]
    loss_fn = make_loss(apply_fn)

    rows = []
    for algo, robust in (("adgda", True), ("unweighted", False)):
        data = rotated_minority_classification(
            num_nodes=m, minority_nodes=minority_nodes, seed=0
        )
        trainer, _, _ = make_adgda("logistic", m, robust=robust, compressor="q4b")
        params0 = init_fn(data.dim, data.num_classes)
        state = trainer.init(params0, jax.random.PRNGKey(0))
        gen_batches = data.batches(50, seed=0)

        with tempfile.TemporaryDirectory() as tmp:
            prefix = f"{tmp}/consensus_{algo}"

            # ---- the serving side: one classifier engine per node, traffic
            # from the node's local distribution, hot reload + quality probe
            def payload_for(node_data_x, node_data_y):
                n = node_data_x.shape[0]

                def payload(node, rng, plen, max_new):
                    idx = int(rng.integers(0, n))
                    return EvalRequest(
                        features=node_data_x[idx:idx + 1],
                        labels=node_data_y[idx:idx + 1],
                    )

                return payload

            # shared quality probe: ONE jitted forward over both populations
            # per checkpoint step, shared by every node probing that step
            # (m nodes x r reloads collapses to r forwards)
            name_to_idx = {n: i for i, n in enumerate(data.val_names)}
            probe = BatchedProbe(
                apply_fn,
                {name: (data.val_x[name_to_idx[name]],
                        data.val_y[name_to_idx[name]])
                 for name in ("majority", "minority")},
                loss_fn=loss_fn,
            )

            def quality_for(node):
                # node's latent population: minority for the rotated nodes
                return probe.quality_fn(
                    "minority" if node < minority_nodes else "majority"
                )

            class _NodePayload:
                """Route each node's traffic through its own data pool."""

                def __init__(self):
                    self.per_node = [payload_for(data.x[i], data.y[i]) for i in range(m)]

                def __call__(self, node, rng, plen, max_new):
                    return self.per_node[node](node, rng, plen, max_new)

            gen = LoadGenerator(
                LoadGenConfig(num_nodes=m, rate=0.8, vocab_size=16, seed=1),
                payload=_NodePayload(),
            )
            nodes = [
                FleetNode(
                    i,
                    ClassifierEngine(apply_fn, params0, max_slots=4),
                    admission=AdmissionControl(max_queue=24),
                    reloader=HotReloader(prefix, params0, log=lambda s: None),
                    quality_fn=quality_for(i),
                )
                for i in range(m)
            ]
            fleet = ServingFleet(nodes, gen, reload_every=1)

            # ---- interleave: train a phase, checkpoint consensus
            # (atomic), serve a traffic window against the fresh weights
            first_probe, window_marks = None, []
            for phase in range(phases):
                for _ in range(rounds):
                    xb, yb = next(gen_batches)
                    state, _ = trainer.step(state, (jnp.asarray(xb), jnp.asarray(yb)))
                save(prefix, trainer.network_mean(state), step=(phase + 1) * rounds)
                window_marks.append([len(n.requests) for n in nodes])
                fleet.run(max_requests=fleet.offered + serve_chunk, max_ticks=500_000)
                if first_probe is None:
                    first_probe = [n.quality_timeline[-1][1] for n in nodes]

            reloads = sum(n.reloader.reloads for n in nodes)
            final_probe = [n.quality_timeline[-1][1] for n in nodes]
            served_acc = []
            for node, mark in zip(nodes, window_marks[-1]):
                window = [r for r in node.requests[mark:] if r.status == "done"]
                ok = [int(r.output[0]) == int(r.labels[0]) for r in window]
                served_acc.append(float(np.mean(ok)) if ok else 0.0)
            rows.append({
                "table": "S",
                "kind": "train_serve",
                "fleet": f"m{m}s4",
                "algo": algo,
                "rate": 0.8,
                "requests": fleet.offered,
                "steps": phases * rounds,
                "reloads": reloads,
                "reload_skipped": sum(n.reloader.skipped for n in nodes),
                "first_worst_acc": min(q["acc"] for q in first_probe),
                "worst_node_acc": min(q["acc"] for q in final_probe),
                "mean_node_acc": float(np.mean([q["acc"] for q in final_probe])),
                "worst_node_loss": max(q["loss"] for q in final_probe),
                "served_worst_acc": min(served_acc),
                # device forwards the shared probe actually ran (float so the
                # row key stays stable across probe batching changes)
                "probe_forwards": float(probe.probe_forwards),
            })
    return rows


def run(quick: bool = True) -> list[dict]:
    return _latency_rows(quick) + _train_serve_rows(quick)


# ------------------------------------------------------------- the scale run
SCALE_REQUESTS = 1_000_000


def run_scale(n_requests: int = SCALE_REQUESTS,
              progress_every: int = 200_000) -> dict:
    """The 10^6-offered-requests end-to-end point (offline — run once via
    ``python -m benchmarks.bench_serving --scale``, persisted to
    ``BENCH_S_SCALE.json`` and referenced from the README; NOT part of the
    quick/full row set so the regression gate's row keys stay stable).

    Fleet m2s2 at the speedup util on the hot-pool (zipf) workload, nodes in
    ``retain="stats"`` mode: every request streams into a constant-size
    accumulator, so memory stays flat while percentiles remain exact.
    Admission conservation (``completed + rejected + shed == offered``) is
    asserted — a lost request anywhere in the pipeline fails the run."""
    import jax as _jax

    from repro.models import transformer as T

    cfg = _serve_cfg()
    params = T.init_model(_jax.random.PRNGKey(0), cfg)
    m, slots = FLEETS["m2s2"]
    capacity = slots / LoadGenConfig_probe(cfg, m).mean_request_tokens()
    rate = round(SPEEDUP_UTIL * capacity, 4)
    rep = _fleet_run(cfg, params, m, slots, rate, n_requests,
                     prompt_mode="pool", retain="stats",
                     progress_every=progress_every)
    f = rep.fleet
    terminal = f["completed"] + f["rejected"] + f["shed"]
    assert terminal == rep.offered, (
        f"admission conservation broken: {f['completed']}+{f['rejected']}"
        f"+{f['shed']} != {rep.offered} offered"
    )
    row = _latency_row(rep, "m2s2", rate, SPEEDUP_UTIL)
    row["prompts"] = "zipf"
    row["kind"] = "scale"
    return row


if __name__ == "__main__":
    import argparse
    import json
    from pathlib import Path

    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", action="store_true",
                    help=f"run the {SCALE_REQUESTS:,}-request scale point and "
                         "write BENCH_S_SCALE.json (offline; ~tens of minutes)")
    ap.add_argument("--requests", type=int, default=SCALE_REQUESTS,
                    help="offered-request count for --scale")
    args = ap.parse_args()
    if args.scale:
        row = run_scale(args.requests)
        out = Path(__file__).resolve().parent.parent / "BENCH_S_SCALE.json"
        out.write_text(json.dumps({"rows": [row]}, indent=1) + "\n")
        print_rows([row])
        print(f"wrote {out}")
    else:
        print_rows(run())
